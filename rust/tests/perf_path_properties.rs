//! Equivalence + complexity pins for the planner hot-path overhaul
//! (interned GPU types, the round-scoped migration index, counter-pinned
//! preview complexity). Same discipline as `ckpt_properties.rs`:
//! deterministic xorshift over many seeds, the seed printed on failure.
//!
//! 1. the indexed migrate (`MigrationIndex::migrate_to`) is BYTE-equal
//!    to the retained reference scan (`ckpt::migrate_reference`) on
//!    random membership×stage layout pairs — same moves, same retained
//!    set, bit-identical transfer seconds;
//! 2. round previews priced against one shared [`RoundIndex`] are
//!    byte-equal to the per-call wrappers, and a greedy extend CHAIN is
//!    byte-equal to the one-shot batch preview (reshard bytes equal,
//!    penalty bit-identical and |Δ| < 1e-12, full Debug render equal);
//! 3. complexity, pinned by the planner's perf counters: a k-offer
//!    greedy `decide_round` prices O(stages × admitted × distinct_types)
//!    previews — NOT O(k²) — and the count is flat in k for a fixed
//!    type set; every preview builds exactly one candidate manifest;
//! 4. the leader's O(1) slot-indexed reply matching routes scrambled
//!    and non-contiguous (post-departure) slot ids correctly;
//! 5. steady-state rounds intern ZERO new bytes
//!    (`intern::stats().bytes_interned` is flat once the type names
//!    have been seen).

use poplar::autoscale::synthesize_curve;
use poplar::ckpt::{self, migrate_reference, MigrationIndex, ShardManifest};
use poplar::cluster::{self, catalog, LinkKind};
use poplar::config::{model::preset, Strategy};
use poplar::coordinator::Leader;
use poplar::curves::PerfCurve;
use poplar::elastic::{ElasticPlanner, XorShift};
use poplar::intern::{self, TypeId};
use poplar::netsim::NetSim;
use poplar::policy::{self, RoundOptions};

const GPUS: &[&str] = &["A800-80G", "V100S-32G", "T4", "RTX4090"];

fn manifest(
    rng: &mut XorShift,
    stage: u8,
    psi: u64,
    slots: &[usize],
    snap: usize,
) -> ShardManifest {
    let with_gpus: Vec<(usize, TypeId)> = slots
        .iter()
        .map(|&s| (s, intern::intern(GPUS[(rng.next() as usize) % GPUS.len()])))
        .collect();
    ShardManifest::build("llama-0.5b", stage, psi, snap, &with_gpus).unwrap()
}

/// A planned ZeRO-1 fleet with every pool type cached at the stage, so
/// previews never need fallbacks and rounds never profile.
fn fleet(n: usize) -> (ElasticPlanner, NetSim) {
    let m = preset("llama-0.5b").unwrap();
    let stage = 1u8;
    let mut p = ElasticPlanner::new(stage, 256, &m.name, m.param_count(), 64);
    for gpu in GPUS {
        let c = synthesize_curve(gpu, &m, stage, n).unwrap();
        p.install_stage_curve(gpu, stage, c).unwrap();
    }
    for i in 0..n {
        let gpu = GPUS[i % GPUS.len()];
        let slot = p.add_slot(gpu);
        if p.slots()[slot].curve.is_none() {
            let c = synthesize_curve(gpu, &m, stage, n).unwrap();
            p.install_curve(slot, c, false).unwrap();
        }
    }
    let net = NetSim::from_link(n, LinkKind::Ib);
    p.replan(&net).unwrap();
    (p, net)
}

#[test]
fn prop_indexed_migrate_byte_equal_to_reference() {
    for seed in 0..120u64 {
        let mut rng = XorShift::new(seed + 42);
        let psi = rng.range(100, 1_000_000_000);
        let stage_a = (rng.next() % 4) as u8;
        let stage_b = (rng.next() % 4) as u8;
        let n0 = rng.range(1, 9) as usize;
        let mut slots: Vec<usize> = (0..n0).collect();
        let old = manifest(&mut rng, stage_a, psi, &slots, 0);
        let mut next_slot = n0;
        for _ in 0..rng.range(0, 4) {
            if rng.uniform() < 0.5 && slots.len() > 1 {
                let i = (rng.next() as usize) % slots.len();
                slots.remove(i);
            } else {
                slots.push(next_slot);
                next_slot += 1;
            }
        }
        let new = manifest(&mut rng, stage_b, psi, &slots, 1);

        let reference = migrate_reference(&old, &new)
            .unwrap_or_else(|e| panic!("seed {seed}: reference: {e}"));
        let idx = MigrationIndex::new(&old)
            .unwrap_or_else(|e| panic!("seed {seed}: index build: {e}"));
        let indexed =
            idx.migrate_to(&new).unwrap_or_else(|e| panic!("seed {seed}: indexed: {e}"));
        // ReshardPlan is PartialEq over every move and retained range:
        // identical emission ORDER included, not just identical sets
        assert_eq!(indexed, reference, "seed {seed}: indexed migrate drifted");

        let net = NetSim::from_link(slots.len().max(1), LinkKind::Ib);
        let (priced, time_s) = idx
            .migrate_to_priced(&new, &net)
            .unwrap_or_else(|e| panic!("seed {seed}: priced: {e}"));
        assert_eq!(priced, reference, "seed {seed}");
        assert_eq!(
            time_s.to_bits(),
            reference.transfer_time_s(&net).to_bits(),
            "seed {seed}: transfer seconds drifted"
        );

        // the binary-search shard_of agrees with the linear scan on
        // every slot id, present or absent
        for s in 0..next_slot + 2 {
            assert_eq!(idx.shard_of(s), old.shard_of(s), "seed {seed} slot {s}");
        }
        // and the public migrate() is a thin wrapper over the index
        let wrapper =
            ckpt::migrate(&old, &new).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(wrapper, reference, "seed {seed}");
    }
}

#[test]
fn prop_round_preview_extend_chain_byte_equal_to_batch() {
    for seed in 0..40u64 {
        let mut rng = XorShift::new(seed + 300);
        let n0 = rng.range(2, 7) as usize;
        let (mut p, _) = fleet(n0);

        // random membership drift with replans between — the index must
        // price correctly against whatever incumbent layout results
        for _ in 0..rng.range(0, 3) {
            let alive: Vec<usize> =
                p.slots().iter().filter(|s| s.alive).map(|s| s.slot).collect();
            if rng.uniform() < 0.4 && alive.len() > 2 {
                let i = (rng.next() as usize) % alive.len();
                p.lose_slot(alive[i]).unwrap();
            } else {
                let gpu = GPUS[(rng.next() as usize) % GPUS.len()];
                p.add_slot(gpu);
            }
            let alive = p.slots().iter().filter(|s| s.alive).count();
            let net = NetSim::from_link(alive, LinkKind::Ib);
            p.replan(&net).unwrap_or_else(|e| panic!("seed {seed}: replan: {e}"));
        }
        let alive = p.slots().iter().filter(|s| s.alive).count();
        let net = NetSim::from_link(alive, LinkKind::Ib);

        let k = rng.range(1, 6) as usize;
        let tys: Vec<TypeId> = (0..k)
            .map(|_| intern::intern(GPUS[(rng.next() as usize) % GPUS.len()]))
            .collect();
        let fallbacks: Vec<Option<PerfCurve>> = vec![None; k];
        let stage = 1u8;

        let idx = p.round_index().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let batch = p
            .preview_round_at_with(&idx, stage, &tys, &fallbacks, &net)
            .unwrap_or_else(|e| panic!("seed {seed}: batch preview: {e}"));

        // greedy-style growth: extend one joiner at a time off the SAME
        // round index, exactly like search_greedy does
        let mut chain = p
            .preview_round_at_with(&idx, stage, &tys[..1], &fallbacks[..1], &net)
            .unwrap_or_else(|e| panic!("seed {seed}: chain seed: {e}"));
        for &t in &tys[1..] {
            chain = p
                .preview_round_extend_with(&idx, &chain, t, None, &net)
                .unwrap_or_else(|e| panic!("seed {seed}: extend: {e}"));
        }
        assert_eq!(batch.manifest, chain.manifest, "seed {seed}: manifest drifted");
        assert_eq!(batch.reshard_bytes, chain.reshard_bytes, "seed {seed}");
        assert!(
            (batch.reshard_penalty_s - chain.reshard_penalty_s).abs() < 1e-12,
            "seed {seed}: penalty drifted by {}",
            (batch.reshard_penalty_s - chain.reshard_penalty_s).abs()
        );
        assert_eq!(
            batch.reshard_penalty_s.to_bits(),
            chain.reshard_penalty_s.to_bits(),
            "seed {seed}: penalty not bit-identical"
        );
        // the full render (plan, curves, net, ledger itemization —
        // everything) must match byte for byte
        assert_eq!(
            format!("{batch:?}"),
            format!("{chain:?}"),
            "seed {seed}: extend chain is not byte-equal to the batch preview"
        );

        // and the wrapper (per-call index build) is byte-equal to the
        // shared-index path
        let wrapper = p
            .preview_round_at(stage, &tys, &fallbacks, &net)
            .unwrap_or_else(|e| panic!("seed {seed}: wrapper: {e}"));
        assert_eq!(format!("{wrapper:?}"), format!("{batch:?}"), "seed {seed}");
    }
}

#[test]
fn greedy_round_preview_count_is_linear_not_quadratic() {
    let m = preset("llama-0.5b").unwrap();
    let opts = RoundOptions::default();
    let distinct = GPUS.len();

    let priced_for = |k: usize| -> (u64, u64) {
        let (p, net) = fleet(8);
        let offers: Vec<String> =
            (0..k).map(|i| GPUS[i % distinct].to_string()).collect();
        let before_p = p.perf().previews_priced();
        let before_m = p.perf().manifests_built();
        policy::decide_round(&p, &net, &m, &offers, &opts).unwrap();
        (
            p.perf().previews_priced() - before_p,
            p.perf().manifests_built() - before_m,
        )
    };

    // k > MAX_EXHAUSTIVE_OFFERS so Auto dispatches to the greedy search
    let k = 32;
    let (priced, manifests) = priced_for(k);
    assert!(priced > 0, "greedy round priced nothing");
    // every growth step prices at most one preview per distinct unused
    // type, over 4 candidate stages and at most cap+1 steps (the last
    // finds no improvement) — generous slack for the seed evaluations
    let cap = k.min(64);
    let bound = (4 * (cap + 2) * distinct) as u64;
    assert!(
        priced <= bound,
        "k={k}: {priced} previews priced, bound {bound} — the round is re-pricing \
         per offer instead of per distinct type"
    );
    assert!(
        priced < (k * k) as u64,
        "k={k}: {priced} previews priced — quadratic in the batch size"
    );
    // pure previews: each builds exactly one candidate manifest
    assert_eq!(manifests, priced, "a preview must build exactly one manifest");

    // the count is FLAT in k for a fixed type set: duplicates of an
    // already-seen type are skipped, never re-priced
    let (priced_2k, _) = priced_for(2 * k);
    assert_eq!(
        priced, priced_2k,
        "doubling duplicate offers changed the preview count — \
         the distinct-type skip regressed"
    );
}

#[test]
fn leader_reply_matching_routes_scrambled_and_sparse_slots() {
    let cluster = cluster::cluster_c();
    let model = preset("llama-0.5b").unwrap();
    let mut l = Leader::new_simulated(&cluster, &model, 0.0, 3);

    // scrambled, non-contiguous request order: replies arrive in any
    // order, results must land at the REQUEST position of their slot
    let subset = [5usize, 0, 7, 2];
    let res = l.profile_slots(&subset, 1).unwrap();
    assert_eq!(res.len(), subset.len());
    for (i, r) in res.iter().enumerate() {
        assert!(r.is_some(), "slot {} returned no profile", subset[i]);
    }

    // after a departure the slot space has a hole; both the profile and
    // the iteration reply paths must still route by slot id
    l.remove_rank(3).unwrap();
    let prof = l.profile(1).unwrap();
    assert_eq!(prof.ranks.len(), 7);
    let plan = l.plan_from_profile(&prof, Strategy::Poplar, 256).unwrap();
    let it = l.run_iteration(&plan).unwrap();
    assert!(it.wall_s > 0.0);
    assert_eq!(it.busy_s.len(), 7);
    l.shutdown();
}

#[test]
fn steady_state_rounds_intern_zero_new_bytes() {
    // pre-intern every name this test binary can touch, so a parallel
    // test interning the same working set cannot perturb the snapshot
    for g in catalog::NAMES {
        let _ = intern::intern(g);
    }
    let _ = intern::intern("llama-0.5b");

    let m = preset("llama-0.5b").unwrap();
    let (p, net) = fleet(6);
    let opts = RoundOptions::default();
    let offers: Vec<String> = (0..12).map(|i| GPUS[i % GPUS.len()].to_string()).collect();
    // warm one full round, then snapshot: the steady state begins here
    policy::decide_round(&p, &net, &m, &offers, &opts).unwrap();
    let before = intern::stats().bytes_interned;

    for _ in 0..5 {
        policy::decide_round(&p, &net, &m, &offers, &opts).unwrap();
        let idx = p.round_index().unwrap();
        let tys: Vec<TypeId> = GPUS.iter().map(|g| intern::intern(g)).collect();
        let fallbacks: Vec<Option<PerfCurve>> = vec![None; tys.len()];
        let pv = p.preview_round_at_with(&idx, 1, &tys, &fallbacks, &net).unwrap();
        let _ = p.preview_round_extend_with(&idx, &pv, tys[0], None, &net).unwrap();
    }
    assert_eq!(
        intern::stats().bytes_interned,
        before,
        "steady-state rounds minted new interned strings — a hot path is \
         interning per candidate instead of per round"
    );
}
