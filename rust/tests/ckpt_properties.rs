//! Property tests over the checkpoint/reshard invariants (same
//! discipline as `elastic_properties.rs`: deterministic xorshift over
//! many seeds, the seed printed on failure). The invariants:
//!
//! 1. serialization round-trips: `from_text(to_text(m)) == m` (and the
//!    disk path `load(save(m))` likewise) for any valid manifest;
//! 2. for ANY random membership event sequence, `ckpt::reshard` covers
//!    every destination's new shard exactly — the union of its moved and
//!    retained ranges equals its new range with no overlap — and for the
//!    partitioned stages the destination ranges tile `[0, ψ)`;
//! 3. every move is sourced correctly: surviving old owners serve their
//!    former bytes, only departed owners' bytes come off the checkpoint;
//! 4. minimality: the reshard never moves more bytes than the
//!    full-restore recompute baseline, and moves zero when the
//!    membership is unchanged;
//! 5. cross-stage migration (`ckpt::migrate`) keeps 1-3 under ANY
//!    stage→stage transition: exact destination coverage, correct
//!    sourcing, zero cost for same-membership stage changes that keep
//!    the partition rule, and migrate-then-migrate-back never loses a
//!    byte.

use poplar::ckpt::{migrate, reshard, ReshardPlan, ShardManifest, ShardRange};
use poplar::elastic::XorShift;
use poplar::zero::OPTIMIZER_BYTES_PER_PARAM;

const GPUS: &[&str] = &["A100-80G", "A800-80G", "V100S-32G", "T4"];

fn manifest(
    rng: &mut XorShift,
    stage: u8,
    psi: u64,
    slots: &[usize],
    snap: usize,
) -> ShardManifest {
    let with_gpus: Vec<(usize, poplar::intern::TypeId)> = slots
        .iter()
        .map(|&s| (s, poplar::intern::intern(GPUS[(rng.next() as usize) % GPUS.len()])))
        .collect();
    ShardManifest::build("llama-0.5b", stage, psi, snap, &with_gpus).unwrap()
}

/// Sorted, merged view of a slot's covered ranges (moved + retained).
fn coverage_of(plan: &ReshardPlan, slot: usize) -> Vec<ShardRange> {
    let mut ranges: Vec<ShardRange> = plan
        .moves
        .iter()
        .filter(|m| m.to_slot == slot)
        .map(|m| m.range)
        .chain(plan.retained.iter().filter(|r| r.slot == slot).map(|r| r.range))
        .collect();
    ranges.sort_by_key(|r| r.lo);
    ranges
}

#[test]
fn prop_text_and_disk_roundtrip_identity() {
    let dir = std::env::temp_dir()
        .join(format!("poplar-ckpt-prop-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    for seed in 0..60u64 {
        let mut rng = XorShift::new(seed);
        let stage = (rng.next() % 4) as u8;
        let psi = rng.range(1_000, 2_000_000_000);
        let n = rng.range(1, 12) as usize;
        // arbitrary, non-contiguous slot ids
        let slots: Vec<usize> = (0..n).map(|i| i * 2 + (seed as usize % 3)).collect();
        let m = manifest(&mut rng, stage, psi, &slots, seed as usize);
        m.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));

        let back = ShardManifest::from_text(&m.to_text())
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(back, m, "seed {seed}: text round-trip drifted");

        let path = m.save(&dir).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let loaded = ShardManifest::load(&path).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(loaded, m, "seed {seed}: disk round-trip drifted");
        let latest =
            ShardManifest::load_latest(&dir).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(latest, m, "seed {seed}: LATEST pointer stale");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prop_reshard_covers_every_destination_exactly_no_overlap() {
    for seed in 0..80u64 {
        let mut rng = XorShift::new(seed + 100);
        let stage = (rng.next() % 4) as u8;
        let psi = rng.range(100, 1_000_000_000);
        let n0 = rng.range(1, 8) as usize;
        let mut slots: Vec<usize> = (0..n0).collect();
        let mut next_slot = n0;
        let mut old = manifest(&mut rng, stage, psi, &slots, 0);

        for step in 0..rng.range(1, 10) {
            // random membership event batch: losses (keeping >= 1 rank)
            // and joins, possibly several at once
            for _ in 0..rng.range(1, 3) {
                if rng.uniform() < 0.5 && slots.len() > 1 {
                    let idx = (rng.next() as usize) % slots.len();
                    slots.remove(idx);
                } else {
                    slots.push(next_slot);
                    next_slot += 1;
                }
            }
            let new = manifest(&mut rng, stage, psi, &slots, step as usize + 1);
            let plan = reshard(&old, &new)
                .unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"));

            // every destination's new range is covered exactly once
            for e in &new.shards {
                let cov = coverage_of(&plan, e.slot);
                let mut cursor = e.range.lo;
                for r in &cov {
                    assert_eq!(
                        r.lo, cursor,
                        "seed {seed} step {step}: slot {} gap/overlap at {cursor}",
                        e.slot
                    );
                    cursor = r.hi;
                }
                assert_eq!(
                    cursor, e.range.hi,
                    "seed {seed} step {step}: slot {} covered to {cursor} of {}",
                    e.slot, e.range.hi
                );
            }
            // partitioned stages: destinations tile the whole space, so
            // moved + retained account for exactly 12ψ bytes
            if stage > 0 {
                assert_eq!(
                    plan.bytes_moved() + plan.bytes_retained(),
                    psi * OPTIMIZER_BYTES_PER_PARAM,
                    "seed {seed} step {step}"
                );
            }
            // sources: surviving owners serve, departed owners -> checkpoint
            for m in &plan.moves {
                match m.from_slot {
                    Some(src) => {
                        assert!(new.has_slot(src), "seed {seed} step {step}: dead source {src}");
                        if stage > 0 {
                            let owned = old.shard_of(src).unwrap();
                            assert!(
                                owned.intersect(&m.range) == Some(m.range),
                                "seed {seed} step {step}: slot {src} never owned {:?}",
                                m.range
                            );
                        }
                    }
                    None => {
                        if stage > 0 {
                            let owner = old
                                .shards
                                .iter()
                                .find(|o| o.range.intersect(&m.range) == Some(m.range));
                            assert!(
                                owner.is_some_and(|o| !new.has_slot(o.slot)),
                                "seed {seed} step {step}: checkpoint used for bytes with a \
                                 surviving owner"
                            );
                        }
                    }
                }
            }
            // minimality vs the recompute baseline
            let recompute = ReshardPlan::full_restore(&new);
            assert!(
                plan.bytes_moved() <= recompute.bytes_moved(),
                "seed {seed} step {step}: reshard moved more than a full restore"
            );
            old = new;
        }
    }
}

/// Assert that `plan` covers every destination of `new` exactly once
/// (no gap, no overlap) and that every move is sourced correctly.
fn assert_exact_coverage(
    plan: &ReshardPlan,
    old: &ShardManifest,
    new: &ShardManifest,
    tag: &str,
) {
    for e in &new.shards {
        let cov = coverage_of(plan, e.slot);
        let mut cursor = e.range.lo;
        for r in &cov {
            assert_eq!(r.lo, cursor, "{tag}: slot {} gap/overlap at {cursor}", e.slot);
            cursor = r.hi;
        }
        assert_eq!(
            cursor, e.range.hi,
            "{tag}: slot {} covered to {cursor} of {}",
            e.slot, e.range.hi
        );
    }
    // accounting: moved + retained equals the total destination volume
    // (ψ for partitioned destinations, n·ψ for replicated ones)
    let dest_total: u64 = new.shards.iter().map(|e| e.range.len()).sum();
    assert_eq!(
        plan.bytes_moved() + plan.bytes_retained(),
        dest_total * OPTIMIZER_BYTES_PER_PARAM,
        "{tag}: byte accounting"
    );
    // sourcing: a surviving owner serves its own former bytes; the
    // checkpoint serves a piece only when EVERY old owner of it departed
    // (replicated old layouts have many owners per piece)
    for m in &plan.moves {
        let owners: Vec<usize> = old
            .shards
            .iter()
            .filter(|o| o.range.intersect(&m.range) == Some(m.range))
            .map(|o| o.slot)
            .collect();
        match m.from_slot {
            Some(src) => {
                assert!(new.has_slot(src), "{tag}: dead source {src}");
                assert!(
                    owners.contains(&src),
                    "{tag}: slot {src} never owned {:?}",
                    m.range
                );
            }
            None => {
                assert!(!owners.is_empty(), "{tag}: checkpoint move for unowned bytes");
                assert!(
                    owners.iter().all(|s| !new.has_slot(*s)),
                    "{tag}: checkpoint used although an owner survived"
                );
            }
        }
    }
}

#[test]
fn prop_cross_stage_migration_covers_every_destination_exactly() {
    for seed in 0..80u64 {
        let mut rng = XorShift::new(seed + 5000);
        let psi = rng.range(100, 1_000_000_000);
        let mut stage = (rng.next() % 4) as u8;
        let n0 = rng.range(1, 8) as usize;
        let mut slots: Vec<usize> = (0..n0).collect();
        let mut next_slot = n0;
        let mut old = manifest(&mut rng, stage, psi, &slots, 0);

        for step in 0..rng.range(1, 8) {
            // random membership drift (possibly none — a pure stage
            // change) plus a random, possibly equal, new stage
            for _ in 0..rng.range(0, 2) {
                if rng.uniform() < 0.5 && slots.len() > 1 {
                    let idx = (rng.next() as usize) % slots.len();
                    slots.remove(idx);
                } else {
                    slots.push(next_slot);
                    next_slot += 1;
                }
            }
            let new_stage = (rng.next() % 4) as u8;
            let new = manifest(&mut rng, new_stage, psi, &slots, step as usize + 1);
            let plan = migrate(&old, &new)
                .unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"));
            assert_eq!(plan.from_stage, stage, "seed {seed} step {step}");
            assert_eq!(plan.stage, new_stage, "seed {seed} step {step}");
            assert_eq!(plan.is_migration(), stage != new_stage);
            assert_exact_coverage(&plan, &old, &new, &format!("seed {seed} step {step}"));
            old = new;
            stage = new_stage;
        }
    }
}

#[test]
fn prop_migrate_then_migrate_back_never_loses_bytes() {
    for seed in 0..60u64 {
        let mut rng = XorShift::new(seed + 7000);
        let psi = rng.range(100, 1_000_000_000);
        let stage = (rng.next() % 4) as u8;
        let back_stage = (rng.next() % 4) as u8;
        let n = rng.range(1, 9) as usize;
        let slots: Vec<usize> = (0..n).collect();
        let a = manifest(&mut rng, stage, psi, &slots, 0);

        let (b, there) = a
            .migrate(back_stage)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_exact_coverage(&there, &a, &b, &format!("seed {seed} there"));
        let (c, back) = b
            .migrate(stage)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_exact_coverage(&back, &b, &c, &format!("seed {seed} back"));

        // the round trip restores the exact original layout: same slots,
        // same ranges — no byte lost, none duplicated
        assert_eq!(c.stage, a.stage, "seed {seed}");
        assert_eq!(c.shards.len(), a.shards.len(), "seed {seed}");
        for (ca, aa) in c.shards.iter().zip(&a.shards) {
            assert_eq!(ca.slot, aa.slot, "seed {seed}");
            assert_eq!(ca.range, aa.range, "seed {seed}: range drifted on round trip");
        }
        // nothing ever sources from the checkpoint: membership is stable
        assert_eq!(there.bytes_from_checkpoint(), 0, "seed {seed}");
        assert_eq!(back.bytes_from_checkpoint(), 0, "seed {seed}");
    }
}

#[test]
fn prop_same_membership_migration_cost_by_direction() {
    // with unchanged membership: stage-unchanged and any
    // partition↔partition or replicate→partition migration move zero
    // bytes; only partition→replicate pays (the broadcast)
    for seed in 0..60u64 {
        let mut rng = XorShift::new(seed + 8000);
        let psi = rng.range(100, 1_000_000);
        let from = (rng.next() % 4) as u8;
        let to = (rng.next() % 4) as u8;
        let n = rng.range(1, 9) as usize;
        let slots: Vec<usize> = (0..n).collect();
        let a = manifest(&mut rng, from, psi, &slots, 0);
        let (_, plan) = a.migrate(to).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let expect_free = to != 0 || from == 0 || n == 1;
        assert_eq!(
            plan.is_noop(),
            expect_free,
            "seed {seed}: ZeRO-{from} -> ZeRO-{to} over {n} ranks moved {} bytes",
            plan.bytes_moved()
        );
        if from == to {
            assert!(plan.is_noop(), "seed {seed}: stage unchanged must cost zero");
        }
    }
}

#[test]
fn prop_unchanged_membership_is_noop() {
    for seed in 0..40u64 {
        let mut rng = XorShift::new(seed + 900);
        let stage = (rng.next() % 4) as u8;
        let psi = rng.range(100, 1_000_000);
        let n = rng.range(1, 9) as usize;
        let slots: Vec<usize> = (0..n).collect();
        let a = manifest(&mut rng, stage, psi, &slots, 0);
        let b = manifest(&mut rng, stage, psi, &slots, 1);
        let plan = reshard(&a, &b).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert!(plan.is_noop(), "seed {seed}: same membership must move nothing");
        assert_eq!(plan.bytes_moved(), 0, "seed {seed}");
    }
}
