//! Integration tests: whole-pipeline flows across modules (config →
//! coordinator → allocator → zero engine → metrics), plus the paper's
//! headline claims as executable assertions.

use poplar::allocator::Plan;
use poplar::cluster::{self, ClusterSpec, LinkKind};
use poplar::config::{model::preset, JobConfig, Strategy};
use poplar::coordinator::Leader;
use poplar::exp;
use poplar::netsim::NetSim;
use poplar::zero::{simulate_iteration, DeviceOracle};

fn oracle_for<'a>(
    cluster: &ClusterSpec,
    model: &'a poplar::config::model::ModelSpec,
) -> DeviceOracle<'a> {
    DeviceOracle {
        specs: cluster.instances().into_iter().map(|i| i.spec).collect(),
        model,
    }
}

#[test]
fn config_to_simulation_pipeline() {
    let cfg = JobConfig::from_toml(
        r#"
        [model]
        preset = "llama-0.5b"
        [cluster]
        preset = "cluster-B"
        [training]
        zero_stage = 1
        global_batch_tokens = 1048576
        iterations = 2
        noise_sigma = 0.01
    "#,
    )
    .unwrap();
    let mut leader = Leader::new_simulated(
        &cfg.cluster,
        &cfg.model,
        cfg.training.noise_sigma,
        cfg.training.seed,
    );
    let rep = leader
        .run_job(cfg.training.zero_stage, cfg.training.strategy, cfg.gbs_samples(), 2)
        .unwrap();
    assert_eq!(rep.iterations.len(), 2);
    assert!(rep.tflops_mean > 0.0);
    assert_eq!(rep.plan.total_samples(), cfg.gbs_samples());
    leader.shutdown();
}

#[test]
fn paper_headline_poplar_never_loses_to_deepspeed() {
    // Fig. 3 claim as an assertion over all three clusters x stages.
    let model = preset("llama-0.5b").unwrap();
    let gbs = exp::gbs_samples(&model);
    for cluster in [cluster::cluster_a(), cluster::cluster_b(), cluster::cluster_c()] {
        for stage in 0..4u8 {
            let pop =
                exp::eval_system(&cluster, &model, stage, Strategy::Poplar, gbs, 21).unwrap();
            let uni =
                exp::eval_system(&cluster, &model, stage, Strategy::Uniform, gbs, 21).unwrap();
            assert!(
                pop.tflops >= uni.tflops * 0.98,
                "{} ZeRO-{stage}: poplar {:.1} vs deepspeed {:.1}",
                cluster.name,
                pop.tflops,
                uni.tflops
            );
        }
    }
}

#[test]
fn plans_transfer_between_planner_and_engine() {
    // a plan computed from noisy profiles must execute OOM-free on the
    // ground-truth devices (the paper's "no OOM later" guarantee),
    // because Alg. 1's mbs came from real OOM probes.
    let cluster = cluster::cluster_c();
    let model = preset("llama-1.1b").unwrap();
    let mut leader = Leader::new_simulated(&cluster, &model, 0.02, 5);
    let prof = leader.profile(2).unwrap();
    let plan = leader
        .plan_from_profile(&prof, Strategy::Poplar, exp::gbs_samples(&model))
        .unwrap();
    // live run errors out if any rank OOMs
    let it = leader.run_iteration(&plan).unwrap();
    assert!(it.wall_s > 0.0);
    leader.shutdown();
}

#[test]
fn simulated_and_live_timings_agree_without_noise() {
    // the zero engine (analytic) and the live worker path must agree on
    // wall time when measurement noise is off — two implementations of
    // the same BSP semantics.
    let cluster = cluster::cluster_c();
    let model = preset("llama-0.5b").unwrap();
    let mut leader = Leader::new_simulated(&cluster, &model, 0.0, 5);
    for stage in [0u8, 2] {
        let prof = leader.profile(stage).unwrap();
        let plan: Plan = leader.plan_from_profile(&prof, Strategy::Poplar, 256).unwrap();
        let live = leader.run_iteration(&plan).unwrap();
        let net = NetSim::from_cluster(&cluster);
        let sim = simulate_iteration(&plan, &oracle_for(&cluster, &model), &net, &model).unwrap();
        let rel = (live.wall_s - sim.wall_s).abs() / sim.wall_s;
        assert!(
            rel < 0.02,
            "stage {stage}: live {:.4}s vs sim {:.4}s (rel {rel:.3})",
            live.wall_s,
            sim.wall_s
        );
    }
    leader.shutdown();
}

#[test]
fn quantity_heterogeneity_all_ratios_plan_and_run() {
    // Fig. 5's non-uniform counts must all produce valid, runnable plans
    // (Whale/AMP cannot even express 4:1).
    let model = preset("llama-0.5b").unwrap();
    for (na, nv) in [(4usize, 1usize), (1, 4), (3, 2), (2, 3)] {
        let cluster = cluster::cluster_c_counts(na, nv);
        let mut leader = Leader::new_simulated(&cluster, &model, 0.01, 8);
        let rep = leader.run_job(3, Strategy::Poplar, 300, 1).unwrap();
        assert_eq!(rep.plan.total_samples(), 300, "{na}:{nv}");
        leader.shutdown();
    }
}

#[test]
fn stage_escalation_consistent_between_profiler_and_memmodel() {
    // the profiler escalates exactly when the memory model says a single
    // sample cannot fit
    let model = preset("llama-1.1b").unwrap();
    let cluster = cluster::cluster_b(); // V100-16G + T4-16G
    let mut leader = Leader::new_simulated(&cluster, &model, 0.0, 4);
    let prof = leader.profile(0).unwrap();
    // 1.1B: 16 bytes/param at stage 0 = 17.6 GB > 16 GiB -> must escalate
    assert!(prof.stage >= 1, "profiled at stage {}", prof.stage);
    for r in &prof.ranks {
        assert!(r.mbs >= 1, "rank {} has mbs 0 after escalation", r.rank);
    }
    leader.shutdown();
}

#[test]
fn socket_network_shifts_plans_toward_fewer_rounds() {
    // ZeRO-3 over sockets should pick gas no larger than over IB.
    let model = preset("llama-0.5b").unwrap();
    let gas_of = |link: LinkKind| -> usize {
        let cluster = ClusterSpec::new(
            "x",
            &[("A800-80G", 2, LinkKind::Pcie), ("V100S-32G", 2, LinkKind::Pcie)],
            link,
        );
        let mut leader = Leader::new_simulated(&cluster, &model, 0.0, 6);
        let prof = leader.profile(3).unwrap();
        let plan = leader.plan_from_profile(&prof, Strategy::Poplar, 1024).unwrap();
        leader.shutdown();
        plan.ranks.iter().map(|r| r.grad_accum_steps).max().unwrap()
    };
    assert!(gas_of(LinkKind::Socket) <= gas_of(LinkKind::Ib));
}

#[test]
fn zero3_comm_identity_in_engine() {
    // the paper's 24 d h^2 FFN identity must hold in the netsim
    assert_eq!(poplar::netsim::zero3_ffn_comm_volume(2048, 8), 24 * 8 * 2048 * 2048);
}

#[test]
fn exp_harness_writes_results() {
    let dir = std::env::temp_dir().join("poplar_test_results");
    let _ = std::fs::remove_dir_all(&dir);
    let t = exp::fig6::run().unwrap();
    exp::write_result(&dir, "fig6", "test", &t).unwrap();
    assert!(dir.join("fig6.md").exists());
    assert!(dir.join("fig6.csv").exists());
    let md = std::fs::read_to_string(dir.join("fig6.md")).unwrap();
    assert!(md.contains("| gpu |"));
}
