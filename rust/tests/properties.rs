//! Property-based tests over the coordinator invariants (routing,
//! batching, state). The offline image has no proptest, so cases are
//! generated with a deterministic xorshift generator over many seeds —
//! same discipline (random structure, invariant assertions, seeds
//! printed on failure).

use poplar::allocator::{self, baselines};
use poplar::cluster::{catalog, LinkKind};
use poplar::config::model::preset;
use poplar::curves::{PerfCurve, ProfiledPoint};
use poplar::netsim::NetSim;
use poplar::spline::CubicSpline;

struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }
    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }
    fn uniform(&mut self) -> f64 {
        (self.next() >> 11) as f64 / (1u64 << 53) as f64
    }
    fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next() as usize) % xs.len()]
    }
}

const GPUS: &[&str] = &["A100-80G", "A100-40G", "A800-80G", "V100-16G", "V100S-32G", "T4"];

/// Random realistic curve: device-model times for a random GPU, random
/// mbs, multiplicative jitter.
fn random_curve(rng: &mut Rng) -> PerfCurve {
    let gpu = catalog::spec_or_panic(*rng.pick(GPUS));
    let model = preset("llama-0.5b").unwrap();
    let mbs = rng.range(2, 48) as usize;
    let stride = rng.range(1, 3) as usize;
    let pts: Vec<ProfiledPoint> = (1..=mbs)
        .step_by(stride)
        .chain(std::iter::once(mbs))
        .map(|b| {
            let t = gpu.compute_time(
                (b as u64 * model.seq) as f64,
                model.flops_per_token(),
                model.n_layers as usize,
            );
            ProfiledPoint { batch: b, step_time_s: t * (1.0 + 0.02 * (rng.uniform() - 0.5)) }
        })
        .collect();
    PerfCurve::fit(pts, mbs).unwrap()
}

fn random_cluster_curves(rng: &mut Rng) -> Vec<PerfCurve> {
    let n = rng.range(1, 10) as usize;
    (0..n).map(|_| random_curve(rng)).collect()
}

// ---------------------------------------------------------------------
// Allocator invariants
// ---------------------------------------------------------------------

#[test]
fn prop_zero01_plans_always_cover_gbs_exactly() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed);
        let curves = random_cluster_curves(&mut rng);
        let gbs = rng.range(1, 4096) as usize;
        let plan = allocator::plan_zero01(&curves, (seed % 2) as u8, gbs).unwrap();
        assert_eq!(plan.total_samples(), gbs, "seed {seed}");
        plan.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

#[test]
fn prop_zero23_plans_cover_gbs_with_shared_gas_and_mbs_bounds() {
    let model = preset("llama-0.5b").unwrap();
    for seed in 0..150u64 {
        let mut rng = Rng::new(seed + 1000);
        let curves = random_cluster_curves(&mut rng);
        let n = curves.len();
        let gbs = rng.range(n as u64, 4096) as usize;
        let stage = 2 + (seed % 2) as u8;
        let net = NetSim::from_link(n, *rng.pick(&[LinkKind::Ib, LinkKind::Socket,
                                                   LinkKind::Pcie]));
        let plan =
            allocator::plan_zero23(&curves, stage, gbs, &net, model.param_count()).unwrap();
        assert_eq!(plan.total_samples(), gbs, "seed {seed}");
        plan.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let gases: Vec<usize> = plan
            .ranks
            .iter()
            .filter(|r| r.grad_accum_steps > 0)
            .map(|r| r.grad_accum_steps)
            .collect();
        assert!(gases.windows(2).all(|w| w[0] == w[1]), "seed {seed}: gas {gases:?}");
        for (r, c) in plan.ranks.iter().zip(&curves) {
            assert!(r.micro_batch <= c.mbs(), "seed {seed}: rank {} over mbs", r.rank);
        }
    }
}

#[test]
fn prop_poplar_never_worse_than_uniform_in_predicted_time() {
    let model = preset("llama-0.5b").unwrap();
    for seed in 0..100u64 {
        let mut rng = Rng::new(seed + 2000);
        let curves = random_cluster_curves(&mut rng);
        let n = curves.len();
        let gbs = rng.range(n as u64 * 4, 2048) as usize;
        let net = NetSim::from_link(n, LinkKind::Ib);
        let stage = 2 + (seed % 2) as u8;
        let pop =
            allocator::plan_zero23(&curves, stage, gbs, &net, model.param_count()).unwrap();
        let uni = baselines::plan_uniform(&curves, stage, gbs, &net, model.param_count())
            .unwrap();
        // the t-sweep explores the uniform point too, so predicted wall
        // must be <= uniform's (small slack for the lbs tail)
        assert!(
            pop.predicted_iter_s <= uni.predicted_iter_s * 1.05,
            "seed {seed}: poplar {:.4} vs uniform {:.4}",
            pop.predicted_iter_s,
            uni.predicted_iter_s
        );
    }
}

#[test]
fn prop_flops_plan_covers_gbs() {
    let model = preset("llama-0.5b").unwrap();
    for seed in 0..100u64 {
        let mut rng = Rng::new(seed + 3000);
        let curves = random_cluster_curves(&mut rng);
        let n = curves.len();
        let flops: Vec<f64> = (0..n).map(|_| 50.0 + rng.uniform() * 300.0).collect();
        let gbs = rng.range(1, 2048) as usize;
        let stage = (seed % 4) as u8;
        let net = NetSim::from_link(n, LinkKind::Ib);
        let plan = baselines::plan_flops_proportional(&curves, &flops, stage, gbs, &net,
                                                      model.param_count())
            .unwrap();
        assert_eq!(plan.total_samples(), gbs, "seed {seed} stage {stage}");
    }
}

// ---------------------------------------------------------------------
// Curve invariants
// ---------------------------------------------------------------------

#[test]
fn prop_find_result_always_fits_budget() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed + 4000);
        let c = random_curve(&mut rng);
        for _ in 0..20 {
            let t = rng.uniform() * 2.0 * c.time_at(c.mbs() as f64);
            let b = c.find(t);
            assert!(b <= c.mbs(), "seed {seed}");
            if b > 0 {
                assert!(c.time_at(b as f64) <= t + 1e-12, "seed {seed}: b={b}");
            }
        }
    }
}

#[test]
fn prop_curve_interpolates_all_knots() {
    for seed in 0..100u64 {
        let mut rng = Rng::new(seed + 5000);
        let c = random_curve(&mut rng);
        for p in c.points() {
            let rel = (c.time_at(p.batch as f64) - p.step_time_s).abs() / p.step_time_s;
            assert!(rel < 1e-9, "seed {seed}: knot {} off by {rel}", p.batch);
        }
    }
}

// ---------------------------------------------------------------------
// Spline invariants
// ---------------------------------------------------------------------

#[test]
fn prop_spline_interpolation_and_smoothness() {
    for seed in 0..100u64 {
        let mut rng = Rng::new(seed + 6000);
        let n = rng.range(3, 20) as usize;
        let mut xs: Vec<f64> = (0..n).map(|i| i as f64 + rng.uniform() * 0.5).collect();
        xs.sort_by(f64::total_cmp);
        xs.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        if xs.len() < 3 {
            continue;
        }
        let ys: Vec<f64> = xs.iter().map(|_| rng.uniform() * 10.0 - 5.0).collect();
        let s = CubicSpline::fit(&xs, &ys).unwrap();
        // interpolation
        for (x, y) in xs.iter().zip(&ys) {
            assert!((s.eval(*x) - y).abs() < 1e-9, "seed {seed}");
        }
        // C1 continuity at interior knots
        for &x in &xs[1..xs.len() - 1] {
            let dl = s.deriv(x - 1e-7);
            let dr = s.deriv(x + 1e-7);
            assert!((dl - dr).abs() < 1e-3 * (1.0 + dl.abs()), "seed {seed} at {x}");
        }
    }
}

// ---------------------------------------------------------------------
// Netsim invariants
// ---------------------------------------------------------------------

#[test]
fn prop_allreduce_decomposition_holds_everywhere() {
    for seed in 0..100u64 {
        let mut rng = Rng::new(seed + 7000);
        let n = rng.range(2, 64) as usize;
        let link = *rng.pick(&[LinkKind::Nvlink, LinkKind::Pcie, LinkKind::Ib,
                               LinkKind::Socket]);
        let net = NetSim::from_link(n, link);
        let v = rng.range(1, 1 << 32);
        let ar = net.time(poplar::netsim::Collective::AllReduce, v);
        let rs = net.time(poplar::netsim::Collective::ReduceScatter, v);
        let ag = net.time(poplar::netsim::Collective::AllGather, v);
        assert!((ar - (rs + ag)).abs() < 1e-12, "seed {seed}");
        // monotone in volume
        let ar2 = net.time(poplar::netsim::Collective::AllReduce, v * 2);
        assert!(ar2 > ar, "seed {seed}");
    }
}

// ---------------------------------------------------------------------
// Data-loader invariants
// ---------------------------------------------------------------------

#[test]
fn prop_loader_materializes_plans_exactly() {
    use poplar::data::{DynamicLoader, SyntheticStream};
    let model = preset("llama-0.5b").unwrap();
    for seed in 0..100u64 {
        let mut rng = Rng::new(seed + 8000);
        let curves = random_cluster_curves(&mut rng);
        let gbs = rng.range(1, 1024) as usize;
        let stage = (seed % 4) as u8;
        let net = NetSim::from_link(curves.len(), LinkKind::Ib);
        let plan = allocator::plan(&curves, stage, gbs, &net, model.param_count()).unwrap();
        let mut dl = DynamicLoader::new(SyntheticStream::new(seed, 512), 16);
        let batches = dl.iteration(&plan);
        let total: usize = batches.iter().map(|m| m.batch_size).sum();
        assert_eq!(total, gbs, "seed {seed} stage {stage}");
        // every batch's token buffer has the right shape
        for m in &batches {
            assert_eq!(m.tokens.len(), m.batch_size * 17, "seed {seed}");
        }
        // per-rank coverage matches the plan
        for r in &plan.ranks {
            let got: usize = batches
                .iter()
                .filter(|m| m.rank == r.rank)
                .map(|m| m.batch_size)
                .sum();
            assert_eq!(got, r.samples_per_iter, "seed {seed} rank {}", r.rank);
        }
    }
}
