"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py is the
core correctness signal for everything that ends up in the artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attention as kflash
from compile.kernels import ref as kref
from compile.kernels import swiglu as kswiglu

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


# --------------------------------------------------------------------------
# SwiGLU FFN
# --------------------------------------------------------------------------

class TestSwiglu:
    def test_matches_ref_default_tiles(self):
        x = _rand(0, (256, 64))
        w1, w3 = _rand(1, (64, 256), scale=0.1), _rand(2, (64, 256), scale=0.1)
        w2 = _rand(3, (256, 64), scale=0.1)
        out = kswiglu.swiglu_ffn(x, w1, w3, w2)
        ref = kref.swiglu_ffn_ref(x, w1, w3, w2)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    @settings(deadline=None, max_examples=12)
    @given(
        t=st.sampled_from([64, 128, 256]),
        d=st.sampled_from([32, 64]),
        f=st.sampled_from([128, 256]),
        bm=st.sampled_from([32, 64, 128]),
        bf=st.sampled_from([64, 128]),
    )
    def test_matches_ref_tile_sweep(self, t, d, f, bm, bf):
        if t % min(bm, t) or f % min(bf, f):
            return
        x = _rand(10, (t, d))
        w1, w3 = _rand(11, (d, f), scale=0.1), _rand(12, (d, f), scale=0.1)
        w2 = _rand(13, (f, d), scale=0.1)
        out = kswiglu.swiglu_ffn(x, w1, w3, w2, bm=bm, bf=bf)
        ref = kref.swiglu_ffn_ref(x, w1, w3, w2)
        np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)

    def test_single_f_block_equals_multi_block(self):
        """f-dimension accumulation must be exact (gate commutes with split)."""
        x = _rand(20, (128, 32))
        w1, w3 = _rand(21, (32, 256), scale=0.1), _rand(22, (32, 256), scale=0.1)
        w2 = _rand(23, (256, 32), scale=0.1)
        one = kswiglu.swiglu_ffn(x, w1, w3, w2, bf=256)
        many = kswiglu.swiglu_ffn(x, w1, w3, w2, bf=64)
        np.testing.assert_allclose(one, many, rtol=2e-5, atol=2e-5)

    def test_indivisible_token_dim_raises(self):
        x = _rand(30, (100, 32))
        w = _rand(31, (32, 128), scale=0.1)
        w2 = _rand(32, (128, 32), scale=0.1)
        with pytest.raises(AssertionError):
            kswiglu.swiglu_ffn(x, w, w, w2, bm=64)

    def test_ad_wrapper_forward_matches(self):
        x = _rand(40, (128, 32))
        w1, w3 = _rand(41, (32, 128), scale=0.1), _rand(42, (32, 128), scale=0.1)
        w2 = _rand(43, (128, 32), scale=0.1)
        np.testing.assert_allclose(
            kswiglu.swiglu_ffn_ad(x, w1, w3, w2),
            kref.swiglu_ffn_ref(x, w1, w3, w2),
            rtol=2e-5, atol=2e-5,
        )

    def test_ad_wrapper_grad_matches_ref_grad(self):
        x = _rand(50, (128, 32))
        w1, w3 = _rand(51, (32, 128), scale=0.1), _rand(52, (32, 128), scale=0.1)
        w2 = _rand(53, (128, 32), scale=0.1)
        g_pallas = jax.grad(lambda *a: kswiglu.swiglu_ffn_ad(*a).sum(), argnums=(0, 1, 2, 3))(
            x, w1, w3, w2)
        g_ref = jax.grad(lambda *a: kref.swiglu_ffn_ref(*a).sum(), argnums=(0, 1, 2, 3))(
            x, w1, w3, w2)
        for gp, gr in zip(g_pallas, g_ref):
            np.testing.assert_allclose(gp, gr, rtol=2e-5, atol=2e-5)

    def test_vmem_footprint_monotone_in_tiles(self):
        small = kswiglu.vmem_footprint_bytes(64, 256, bm=32, bf=64)
        big = kswiglu.vmem_footprint_bytes(64, 256, bm=128, bf=256)
        assert small < big

    def test_mxu_utilization_peaks_at_multiple_of_128(self):
        aligned = kswiglu.mxu_utilization_estimate(128, 256, bm=128, bf=128)
        ragged = kswiglu.mxu_utilization_estimate(100, 256, bm=96, bf=128)
        assert aligned > ragged
        assert aligned == pytest.approx(1.0)


# --------------------------------------------------------------------------
# Flash attention
# --------------------------------------------------------------------------

class TestFlashAttention:
    def test_matches_ref_causal(self):
        q, k, v = (_rand(i, (2, 256, 32)) for i in (60, 61, 62))
        out = kflash.flash_attention(q, k, v, causal=True)
        ref = kref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_matches_ref_noncausal(self):
        q, k, v = (_rand(i, (2, 128, 32)) for i in (63, 64, 65))
        out = kflash.flash_attention(q, k, v, causal=False)
        ref = kref.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    @settings(deadline=None, max_examples=10)
    @given(
        h=st.sampled_from([1, 2, 4]),
        t=st.sampled_from([128, 256]),
        hd=st.sampled_from([16, 32, 64]),
        bq=st.sampled_from([64, 128]),
        bk=st.sampled_from([32, 64]),
        causal=st.booleans(),
    )
    def test_matches_ref_shape_sweep(self, h, t, hd, bq, bk, causal):
        q, k, v = (_rand(70 + i, (h, t, hd)) for i in range(3))
        out = kflash.flash_attention(q, k, v, bq=bq, bk=bk, causal=causal)
        ref = kref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)

    def test_causal_first_row_attends_only_self(self):
        """Row 0 of causal attention must equal v[0] exactly (softmax of 1)."""
        q, k, v = (_rand(80 + i, (1, 128, 16)) for i in range(3))
        out = kflash.flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(out[0, 0], v[0, 0], rtol=1e-6, atol=1e-6)

    def test_numerical_stability_large_logits(self):
        """Online softmax must not overflow with large score magnitudes."""
        q = _rand(90, (1, 128, 16), scale=30.0)
        k = _rand(91, (1, 128, 16), scale=30.0)
        v = _rand(92, (1, 128, 16))
        out = kflash.flash_attention(q, k, v, causal=True)
        assert bool(jnp.isfinite(out).all())

    def test_ad_wrapper_grad_matches_ref_grad(self):
        q, k, v = (_rand(95 + i, (2, 128, 16)) for i in range(3))
        gp = jax.grad(lambda *a: kflash.flash_attention_ad_causal(*a).sum(), argnums=(0, 1, 2))(
            q, k, v)
        gr = jax.grad(lambda *a: kref.attention_ref(*a, causal=True).sum(), argnums=(0, 1, 2))(
            q, k, v)
        for a, b in zip(gp, gr):
            np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)

    def test_indivisible_seq_raises(self):
        q, k, v = (_rand(99, (1, 100, 16)) for _ in range(3))
        with pytest.raises(AssertionError):
            kflash.flash_attention(q, k, v, bq=64, bk=64)


# --------------------------------------------------------------------------
# RMSNorm oracle sanity
# --------------------------------------------------------------------------

def test_rmsnorm_unit_scale():
    x = _rand(100, (64, 32))
    g = jnp.ones((32,))
    out = kref.rmsnorm_ref(x, g)
    rms = jnp.sqrt(jnp.mean(out**2, axis=-1))
    np.testing.assert_allclose(rms, jnp.ones_like(rms), rtol=1e-3, atol=1e-3)
