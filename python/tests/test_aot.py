"""AOT pipeline tests: HLO text emission, meta ABI, params binary layout."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")

SMALL = M.ModelConfig(vocab=64, d_model=32, n_layers=1, n_heads=2, d_ff=64, seq=64)


@pytest.fixture(scope="module")
def small_preset(tmp_path_factory):
    """Register a throwaway preset and emit its artifacts once."""
    M.PRESETS["_test_small"] = SMALL
    out = str(tmp_path_factory.mktemp("artifacts") / "_test_small")
    aot.emit_preset("_test_small", out, [1, 2], use_pallas=False)
    yield out
    del M.PRESETS["_test_small"]


def test_hlo_text_is_parseable_hlo(small_preset):
    text = open(os.path.join(small_preset, "step_b1.hlo.txt")).read()
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text


def test_emits_all_artifacts(small_preset):
    names = set(os.listdir(small_preset))
    expected = {"step_b1.hlo.txt", "step_b2.hlo.txt", "grad_b1.hlo.txt",
                "grad_b2.hlo.txt", "apply_update.hlo.txt", "params_init.bin",
                "meta.json"}
    assert expected <= names


def test_meta_abi(small_preset):
    meta = json.load(open(os.path.join(small_preset, "meta.json")))
    assert meta["abi"] == "flat-f32-params-v1"
    assert meta["batch_variants"] == [1, 2]
    assert meta["param_count"] == SMALL.param_count()
    shapes = [tuple(p["shape"]) for p in meta["params"]]
    assert shapes == [s for _, s in M.param_specs(SMALL)]


def test_params_bin_size_and_roundtrip(small_preset):
    raw = open(os.path.join(small_preset, "params_init.bin"), "rb").read()
    assert len(raw) == 4 * SMALL.param_count()
    flat = np.frombuffer(raw, dtype="<f4")
    # reconstruct and compare against init_params
    expected = M.init_params(SMALL, seed=0)
    off = 0
    for arr in expected:
        n = int(np.prod(arr.shape))
        np.testing.assert_allclose(flat[off:off + n].reshape(arr.shape), arr, rtol=1e-6)
        off += n
    assert off == len(flat)


def test_hlo_batch_variants_differ(small_preset):
    b1 = open(os.path.join(small_preset, "grad_b1.hlo.txt")).read()
    b2 = open(os.path.join(small_preset, "grad_b2.hlo.txt")).read()
    assert "64" in b1  # seq dim present
    assert b1 != b2


def test_to_hlo_text_roundtrip_simple_fn():
    """Any jitted fn must lower to HLO text with ENTRY + tuple root."""
    lowered = jax.jit(lambda x: (x * 2 + 1,)).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
