"""L2 model correctness: pallas-vs-ref cross-check, training dynamics, ABI."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(vocab=128, d_model=64, n_layers=2, n_heads=2, d_ff=128, seq=128)
BERT_CFG = M.ModelConfig(arch="bert", vocab=128, d_model=64, n_layers=2, n_heads=2,
                         d_ff=128, seq=128)


def _tokens(key, b, cfg=CFG):
    return jax.random.randint(jax.random.PRNGKey(key), (b, cfg.seq + 1), 0, cfg.vocab)


class TestForward:
    def test_logits_shape(self):
        params = M.init_params(CFG)
        logits = M.forward(CFG, params, _tokens(0, 2)[:, :-1])
        assert logits.shape == (2, CFG.seq, CFG.vocab)

    def test_pallas_matches_ref_forward(self):
        params = M.init_params(CFG)
        tok = _tokens(1, 2)[:, :-1]
        ref = M.forward(CFG, params, tok, use_pallas=False)
        pal = M.forward(CFG, params, tok, use_pallas=True)
        np.testing.assert_allclose(pal, ref, rtol=5e-5, atol=5e-5)

    def test_pallas_matches_ref_loss_and_grad(self):
        params = M.init_params(CFG)
        tok = _tokens(2, 1)
        lr, gr = jax.value_and_grad(lambda p: M.loss_fn(CFG, p, tok, False))(params)
        lp, gp = jax.value_and_grad(lambda p: M.loss_fn(CFG, p, tok, True))(params)
        np.testing.assert_allclose(lp, lr, rtol=5e-5)
        for a, b in zip(gp, gr):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_initial_loss_near_uniform(self):
        """Random init should give CE ~= log(vocab)."""
        params = M.init_params(CFG)
        loss = M.loss_fn(CFG, params, _tokens(3, 2))
        assert abs(float(loss) - np.log(CFG.vocab)) < 1.0

    def test_causal_masking(self):
        """Changing a future token must not affect earlier logits (llama)."""
        params = M.init_params(CFG)
        tok = np.asarray(_tokens(4, 1)[:, :-1])
        logits1 = M.forward(CFG, params, jnp.asarray(tok))
        tok2 = tok.copy()
        tok2[0, -1] = (tok2[0, -1] + 1) % CFG.vocab
        logits2 = M.forward(CFG, params, jnp.asarray(tok2))
        np.testing.assert_allclose(logits1[0, :-1], logits2[0, :-1], rtol=1e-5, atol=1e-5)

    def test_bert_is_not_causal(self):
        """BERT attention is bidirectional: future tokens do affect position 0."""
        params = M.init_params(BERT_CFG)
        tok = np.asarray(_tokens(5, 1, BERT_CFG)[:, :-1])
        logits1 = M.forward(BERT_CFG, params, jnp.asarray(tok))
        tok2 = tok.copy()
        tok2[0, -1] = (tok2[0, -1] + 1) % BERT_CFG.vocab
        logits2 = M.forward(BERT_CFG, params, jnp.asarray(tok2))
        assert not np.allclose(logits1[0, 0], logits2[0, 0])


class TestTrainStep:
    def test_loss_decreases(self):
        params = M.init_params(CFG)
        momenta = [jnp.zeros_like(p) for p in params]
        step = jax.jit(M.make_train_step(CFG))
        tok = _tokens(6, 4)
        losses = []
        for _ in range(10):
            out = step(params, momenta, tok)
            n = len(params)
            params, momenta, loss = list(out[:n]), list(out[n:2 * n]), out[-1]
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.2, losses

    def test_grad_step_plus_apply_equals_train_step(self):
        """The multi-rank path (grad + apply) must equal the fused step."""
        params = M.init_params(CFG)
        momenta = [jnp.zeros_like(p) for p in params]
        tok = _tokens(7, 2)
        n = len(params)

        fused = M.make_train_step(CFG)(params, momenta, tok)
        grads_out = M.make_grad_step(CFG)(params, tok)
        grads, loss = list(grads_out[:n]), grads_out[-1]
        applied = M.make_apply_update(CFG)(params, momenta, grads)

        np.testing.assert_allclose(float(loss), float(fused[-1]), rtol=1e-6)
        for a, b in zip(applied[:n], fused[:n]):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

    def test_weighted_grad_average_is_linear(self):
        """Heterogeneous averaging: grad(b1 ∪ b2) == (b1*g1 + b2*g2)/(b1+b2)."""
        params = M.init_params(CFG)
        tok = _tokens(8, 3)
        n = len(params)
        g_all = M.make_grad_step(CFG)(params, tok)[:n]
        g_1 = M.make_grad_step(CFG)(params, tok[:1])[:n]
        g_2 = M.make_grad_step(CFG)(params, tok[1:])[:n]
        for ga, g1, g2 in zip(g_all, g_1, g_2):
            combined = (1 * g1 + 2 * g2) / 3.0
            np.testing.assert_allclose(ga, combined, rtol=1e-4, atol=1e-5)


class TestABI:
    def test_param_specs_deterministic(self):
        assert M.param_specs(CFG) == M.param_specs(CFG)

    def test_param_count_matches_arrays(self):
        params = M.init_params(CFG)
        total = sum(int(np.prod(p.shape)) for p in params)
        assert total == CFG.param_count()

    def test_spec_order_embed_first_head_last(self):
        specs = M.param_specs(CFG)
        assert specs[0][0] == "embed"
        assert specs[-1][0] == "lm_head"

    @pytest.mark.parametrize("preset", sorted(M.PRESETS))
    def test_presets_well_formed(self, preset):
        cfg = M.PRESETS[preset]
        assert cfg.d_model % cfg.n_heads == 0
        assert cfg.param_count() > 0
        assert cfg.flops_per_token() > 6 * cfg.param_count() - 1

    def test_paper_preset_sizes(self):
        """The paper-scale presets should land near their nominal sizes."""
        assert 0.3e9 < M.PRESETS["llama-0.5b"].param_count() < 0.7e9
        assert 0.9e9 < M.PRESETS["llama-1.1b"].param_count() < 1.4e9
        assert 0.9e9 < M.PRESETS["bert-1.1b"].param_count() < 1.4e9
