"""AOT driver: lower the L2 train step to HLO *text* artifacts.

Emits, per model preset:

  artifacts/<preset>/step_b{B}.hlo.txt       fused fwd+bwd+update (single rank)
  artifacts/<preset>/grad_b{B}.hlo.txt       fwd+bwd, raw grads (multi rank)
  artifacts/<preset>/apply_update.hlo.txt    optimizer step on reduced grads
  artifacts/<preset>/params_init.bin         flat f32 little-endian init params
  artifacts/<preset>/meta.json               shapes / ABI / flops — read by rust

One executable per micro-batch-size variant: Poplar assigns each rank its
own batch size, and PJRT executables are shape-specialized, so the rust
runtime keeps a {batch_size -> executable} cache (rust/src/runtime).

Interchange is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser).

    ``return_tuple=True`` (the shipped artifacts) gives a single tuple
    output that rust unpacks from one literal. ``return_tuple=False``
    was explored for a device-resident pipeline but PJRT 0.5.1 via the
    xla crate returns one buffer either way (no output untupling) — see
    EXPERIMENTS.md §Perf.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def emit_preset(preset: str, out_dir: str, batch_variants, use_pallas: bool) -> dict:
    cfg = M.PRESETS[preset]
    os.makedirs(out_dir, exist_ok=True)
    specs = M.param_specs(cfg)
    p_abs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs]
    written = {}

    for b in batch_variants:
        tok = jax.ShapeDtypeStruct((b, cfg.seq + 1), jnp.int32)

        step = M.make_train_step(cfg, use_pallas=use_pallas)
        lowered = jax.jit(lambda p, m, t: step(p, m, t)).lower(p_abs, p_abs, tok)
        path = os.path.join(out_dir, f"step_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        written[f"step_b{b}"] = path

        grad = M.make_grad_step(cfg, use_pallas=use_pallas)
        lowered = jax.jit(lambda p, t: grad(p, t)).lower(p_abs, tok)
        path = os.path.join(out_dir, f"grad_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        written[f"grad_b{b}"] = path

        print(f"[aot] {preset}: batch {b} done")

    apply_u = M.make_apply_update(cfg)
    lowered = jax.jit(lambda p, m, g: apply_u(p, m, g)).lower(p_abs, p_abs, p_abs)
    path = os.path.join(out_dir, "apply_update.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    written["apply_update"] = path

    # Initial parameters: raw little-endian f32, concatenated in spec order.
    params = M.init_params(cfg, seed=0)
    with open(os.path.join(out_dir, "params_init.bin"), "wb") as f:
        for arr in params:
            f.write(np.asarray(arr, dtype="<f4").tobytes())

    meta = {
        "preset": preset,
        "arch": cfg.arch,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "d_ff": cfg.d_ff,
        "seq": cfg.seq,
        "lr": cfg.lr,
        "momentum": cfg.momentum,
        "param_count": int(cfg.param_count()),
        "flops_per_token": float(cfg.flops_per_token()),
        "batch_variants": list(batch_variants),
        "use_pallas": use_pallas,
        "params": [{"name": n, "shape": list(s)} for n, s in specs],
        # step_b{B}:  inputs [*params, *momenta, tokens[B,seq+1]] -> (*params, *momenta, loss)
        # grad_b{B}:  inputs [*params, tokens] -> (*grads, loss)
        # apply_update: [*params, *momenta, *grads] -> (*params, *momenta)
        "abi": "flat-f32-params-v1",
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)

    # Flat-text twin of meta.json for the rust loader (the offline image
    # has no JSON crate; see rust/src/runtime/meta.rs).
    with open(os.path.join(out_dir, "meta.txt"), "w") as f:
        for k in ("preset", "arch", "vocab", "d_model", "n_layers", "n_heads",
                  "d_ff", "seq", "lr", "momentum", "param_count",
                  "flops_per_token", "abi"):
            f.write(f"{k} {meta[k]}\n")
        f.write("use_pallas {}\n".format(1 if use_pallas else 0))
        f.write("batch_variants {}\n".format(",".join(str(b) for b in batch_variants)))
        for n, s in specs:
            f.write("param {} {}\n".format(n, ",".join(str(x) for x in s)))
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts root")
    ap.add_argument("--preset", default="tiny", choices=sorted(M.PRESETS))
    ap.add_argument("--batches", default="1,2,4,8",
                    help="comma-separated micro-batch-size variants")
    ap.add_argument("--no-pallas", action="store_true",
                    help="use the pure-jnp reference instead of Pallas kernels")
    args = ap.parse_args()
    batches = [int(b) for b in args.batches.split(",") if b]
    out_dir = os.path.join(args.out, args.preset)
    emit_preset(args.preset, out_dir, batches, use_pallas=not args.no_pallas)
    print(f"[aot] wrote artifacts for '{args.preset}' to {out_dir}")


if __name__ == "__main__":
    main()
