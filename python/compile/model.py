"""L2: JAX model definitions and the AOT-compiled train step.

Two architectures mirroring the paper's evaluation models:

  * ``llama`` — decoder-only: RMSNorm, rotary attention, SwiGLU FFN
    (the 0.5B / 1.1B Llama configs of Figs. 3-5, scaled down for the
    CPU-only end-to-end run);
  * ``bert``  — encoder-only: bidirectional attention, masked-LM-style
    loss over all positions (Fig. 4c).

The hot paths call the L1 Pallas kernels (``kernels.swiglu``,
``kernels.flash_attention``) when ``use_pallas=True``; the pure-jnp
oracles in ``kernels.ref`` otherwise.  pytest cross-checks the two.

``train_step`` = forward + backward + SGD-with-momentum update, jitted
and lowered per micro-batch-size variant by ``aot.py``.  Parameters are a
*flat list* of arrays (deterministic order via ``param_specs``) so the
rust runtime can thread them through PJRT without pytree knowledge.
"""

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import ref as kref
from compile.kernels import swiglu as kswiglu
from compile.kernels import flash_attention as kflash


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (defaults: the e2e validation model)."""

    arch: str = "llama"          # "llama" | "bert"
    vocab: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 1024             # intermediate size
    seq: int = 256
    lr: float = 3e-3
    momentum: float = 0.9
    tie_embeddings: bool = False

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        total = 0
        for _, shape in param_specs(self):
            n = 1
            for s in shape:
                n *= s
            total += n
        return total

    def flops_per_token(self) -> float:
        """Approximate fwd+bwd FLOPs per token (the 6N rule, attention-aware).

        Matches rust/src/metrics/flops.rs — keep in sync.
        """
        n = self.param_count()
        attn = 12 * self.n_layers * self.d_model * self.seq  # score+value matmuls, fwd+bwd
        return 6.0 * n + attn


# --------------------------------------------------------------------------
# Parameter layout
# --------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) list — the ABI between python and rust."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    specs: List[Tuple[str, Tuple[int, ...]]] = [("embed", (v, d))]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "attn_norm", (d,)),
            (p + "wq", (d, d)),
            (p + "wk", (d, d)),
            (p + "wv", (d, d)),
            (p + "wo", (d, d)),
            (p + "ffn_norm", (d,)),
            (p + "w1", (d, f)),
            (p + "w3", (d, f)),
            (p + "w2", (f, d)),
        ]
    specs.append(("final_norm", (d,)))
    if not cfg.tie_embeddings:
        specs.append(("lm_head", (d, v)))
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jax.Array]:
    """Scaled-normal init, flat list in ``param_specs`` order."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            scale = (1.0 / shape[0]) ** 0.5
            params.append(jax.random.normal(sub, shape, jnp.float32) * scale)
    return params


def _as_dict(cfg: ModelConfig, flat: List[jax.Array]):
    return {name: arr for (name, _), arr in zip(param_specs(cfg), flat)}


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

def _rope(x: jax.Array, base: float = 10000.0) -> jax.Array:
    """Rotary embedding over [H, T, hd]."""
    h, t, hd = x.shape
    half = hd // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = jnp.arange(t, dtype=jnp.float32)[:, None] * freqs[None, :]  # [T, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention_block(x, p, prefix, cfg: ModelConfig, use_pallas: bool, causal: bool):
    """x: [T, d] -> [T, d] (single sequence; vmapped over batch)."""
    d, nh, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    t = x.shape[0]

    def split(y):  # [T, d] -> [H, T, hd]
        return y.reshape(t, nh, hd).transpose(1, 0, 2)

    q = split(x @ p[prefix + "wq"])
    k = split(x @ p[prefix + "wk"])
    v = split(x @ p[prefix + "wv"])
    if causal:  # rotary only for the decoder
        q, k = _rope(q), _rope(k)
    if use_pallas:
        attn = kflash.flash_attention_ad_causal if causal else kflash.flash_attention_ad_full
        o = attn(q, k, v)
    else:
        o = kref.attention_ref(q, k, v, causal=causal)
    o = o.transpose(1, 0, 2).reshape(t, d)
    return o @ p[prefix + "wo"]


def _ffn_block(x, p, prefix, cfg: ModelConfig, use_pallas: bool):
    if use_pallas:
        return kswiglu.swiglu_ffn_ad(x, p[prefix + "w1"], p[prefix + "w3"], p[prefix + "w2"])
    return kref.swiglu_ffn_ref(x, p[prefix + "w1"], p[prefix + "w3"], p[prefix + "w2"])


def forward(cfg: ModelConfig, flat_params: List[jax.Array], tokens: jax.Array,
            use_pallas: bool = False) -> jax.Array:
    """tokens: [B, T] int32 -> logits [B, T, vocab]."""
    p = _as_dict(cfg, flat_params)
    causal = cfg.arch == "llama"

    def one_seq(tok):
        x = p["embed"][tok]  # [T, d]
        for i in range(cfg.n_layers):
            pre = f"layer{i}."
            h = kref.rmsnorm_ref(x, p[pre + "attn_norm"])
            x = x + _attention_block(h, p, pre, cfg, use_pallas, causal)
            h = kref.rmsnorm_ref(x, p[pre + "ffn_norm"])
            x = x + _ffn_block(h, p, pre, cfg, use_pallas)
        x = kref.rmsnorm_ref(x, p["final_norm"])
        head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
        return x @ head

    return jax.vmap(one_seq)(tokens)


def loss_fn(cfg: ModelConfig, flat_params: List[jax.Array], tokens: jax.Array,
            use_pallas: bool = False) -> jax.Array:
    """Next-token cross-entropy. tokens: [B, T+1] int32."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, flat_params, inputs, use_pallas)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# --------------------------------------------------------------------------
# Train step (the AOT unit)
# --------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, use_pallas: bool = False):
    """``step(params, momenta, tokens) -> (*new_params, *new_momenta, loss)``.

    Single-rank path: forward + backward + SGD-momentum update fused in
    one executable.
    """

    def step(params, momenta, tokens):
        loss, grads = jax.value_and_grad(lambda fp: loss_fn(cfg, fp, tokens, use_pallas))(params)
        new_m = [cfg.momentum * m + g for m, g in zip(momenta, grads)]
        new_p = [p - cfg.lr * m for p, m in zip(params, new_m)]
        return tuple(new_p) + tuple(new_m) + (loss,)

    return step


def make_grad_step(cfg: ModelConfig, use_pallas: bool = False):
    """``grad_step(params, tokens) -> (*grads, loss)`` (no update).

    Multi-rank path: gradients are returned raw so the rust coordinator
    can perform the heterogeneous weighted averaging across ranks (each
    rank contributes grad * b_i / gbs) before the shared optimizer step.
    """

    def grad_step(params, tokens):
        loss, grads = jax.value_and_grad(lambda fp: loss_fn(cfg, fp, tokens, use_pallas))(params)
        return tuple(grads) + (loss,)

    return grad_step


def make_apply_update(cfg: ModelConfig):
    """``apply(params, momenta, grads) -> (*new_params, *new_momenta)``.

    The ZeRO optimizer step, applied to the *reduced* gradient after the
    collective.
    """

    def apply(params, momenta, grads):
        new_m = [cfg.momentum * m + g for m, g in zip(momenta, grads)]
        new_p = [p - cfg.lr * m for p, m in zip(params, new_m)]
        return tuple(new_p) + tuple(new_m)

    return apply


# --------------------------------------------------------------------------
# Paper model presets (used by the analytic simulator and aot.py --preset)
# --------------------------------------------------------------------------

PRESETS = {
    # e2e validation models (really trained on CPU)
    "tiny": ModelConfig(vocab=2048, d_model=256, n_layers=4, n_heads=4, d_ff=1024, seq=256),
    "e2e-28m": ModelConfig(vocab=8192, d_model=512, n_layers=6, n_heads=8, d_ff=1536, seq=256),
    "e2e-110m": ModelConfig(vocab=16384, d_model=768, n_layers=12, n_heads=12, d_ff=2304, seq=256),
    # paper evaluation models (analytic simulation only — see DESIGN.md §2)
    "llama-0.5b": ModelConfig(vocab=32000, d_model=1024, n_layers=24, n_heads=16, d_ff=4096, seq=1024),
    "llama-1.1b": ModelConfig(vocab=32000, d_model=2048, n_layers=22, n_heads=32, d_ff=5632, seq=1024),
    "bert-1.1b": ModelConfig(arch="bert", vocab=30522, d_model=1792, n_layers=24, n_heads=28,
                             d_ff=7168, seq=512),
}
