"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground-truth implementations the L1 kernels are validated
against in pytest (assert_allclose). They are also used directly by the
L2 model when ``use_pallas=False`` so the two model variants can be
cross-checked end to end.
"""

import jax
import jax.numpy as jnp


def swiglu_ffn_ref(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    """SwiGLU feed-forward: ``(silu(x @ w1) * (x @ w3)) @ w2``.

    x: [T, d]; w1, w3: [d, f]; w2: [f, d]  ->  [T, d]
    """
    gate = jax.nn.silu(x @ w1)
    up = x @ w3
    return (gate * up) @ w2


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True) -> jax.Array:
    """Scaled dot-product attention oracle.

    q, k, v: [H, T, hd]  ->  [H, T, hd]
    """
    hd = q.shape[-1]
    scores = jnp.einsum("htd,hsd->hts", q, k) / jnp.sqrt(hd).astype(q.dtype)
    if causal:
        t = q.shape[1]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(mask[None, :, :], scores, jnp.finfo(scores.dtype).min)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hts,hsd->htd", probs, v)


def rmsnorm_ref(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm oracle: ``x / rms(x) * g`` over the last axis."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g
