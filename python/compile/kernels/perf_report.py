"""L1 kernel performance report (§Perf): VMEM footprint + MXU-utilization
estimates per BlockSpec candidate.

interpret=True gives CPU-numpy timings only — NOT a TPU proxy — so the
structural metrics below are what we optimize (DESIGN.md
§Hardware-Adaptation): keep the working set under the ~16 MiB VMEM
budget, keep every matmul tile a multiple of the 128x128 MXU.

Usage: python -m compile.kernels.perf_report [d_model] [d_ff]
"""

import sys

from compile.kernels import flash_attention as kflash
from compile.kernels import swiglu as kswiglu

VMEM_BUDGET = 16 * 1024 * 1024  # ~16 MiB usable VMEM per TensorCore


def swiglu_table(d: int, f: int):
    print(f"\nfused SwiGLU FFN, d_model={d}, d_ff={f}")
    print(f"{'bm':>5} {'bf':>5} {'vmem_KiB':>9} {'fits':>5} {'mxu_util':>8}")
    rows = []
    for bm in (64, 128, 256, 512):
        for bf in (128, 256, 512, 1024):
            if bf > f:
                continue
            vmem = kswiglu.vmem_footprint_bytes(d, f, bm=bm, bf=bf)
            util = kswiglu.mxu_utilization_estimate(d, f, bm=bm, bf=bf)
            fits = vmem <= VMEM_BUDGET
            rows.append((bm, bf, vmem, fits, util))
            print(f"{bm:>5} {bf:>5} {vmem // 1024:>9} {str(fits):>5} {util:>8.3f}")
    # Selection: highest MXU utilization, then largest bm (the x tile is
    # reused across the f loop, so total HBM weight traffic is
    # (T/bm)·3·d·f — bigger row tiles stream the weights fewer times),
    # under half the VMEM budget to leave room for double buffering.
    ok = [r for r in rows if r[2] <= VMEM_BUDGET // 2]
    best = max(ok, key=lambda r: (r[4], r[0], r[1]))
    traffic = lambda bm: 3 * d * f / bm  # weight words per token row
    print(f"-> selected BlockSpec: bm={best[0]} bf={best[1]} "
          f"(vmem {best[2] // 1024} KiB of {VMEM_BUDGET // 2048} KiB budget/2, "
          f"mxu {best[4]:.3f}, weight traffic {traffic(best[0]):.0f} words/row "
          f"vs {traffic(64):.0f} at bm=64)")
    return best


def flash_table(t: int, hd: int):
    print(f"\nflash attention, seq={t}, head_dim={hd}")
    print(f"{'bq':>5} {'bk':>5} {'vmem_KiB':>9} {'fits':>5}")
    for bq in (64, 128, 256):
        for bk in (64, 128, 256):
            if bq > t or bk > t:
                continue
            vmem = kflash.vmem_footprint_bytes(t, hd, bq=bq, bk=bk)
            print(f"{bq:>5} {bk:>5} {vmem // 1024:>9} {str(vmem <= VMEM_BUDGET):>5}")


def main():
    d = int(sys.argv[1]) if len(sys.argv) > 1 else 256
    f = int(sys.argv[2]) if len(sys.argv) > 2 else 1024
    swiglu_table(d, f)
    # paper-scale shapes too
    swiglu_table(1024, 4096)
    swiglu_table(2048, 5632)
    flash_table(256, 64)
    flash_table(1024, 64)


if __name__ == "__main__":
    main()
