"""L1 Pallas kernel: fused SwiGLU feed-forward block.

The transformer FFN is the compute hot spot the paper's batch-size /
throughput curves (Fig. 6) are shaped by: cuBLAS tile quantization on GPU,
MXU 128x128 systolic tiles on TPU.  This kernel is the TPU re-think of
that hot spot (DESIGN.md §Hardware-Adaptation):

  * the token dimension ``T = batch x seq`` is tiled into ``bm`` rows —
    the analogue of the CUDA threadblock M-tile;
  * the FFN hidden dimension ``f`` is tiled into ``bf`` columns so the
    three weight matrices stream HBM->VMEM block by block (BlockSpec
    index maps play the role of the CUDA grid schedule);
  * partial products accumulate into the output block, which stays
    resident in VMEM across the ``f`` loop (revision dimension last in
    the grid, so the output BlockSpec ignores it).

``interpret=True`` is mandatory on this CPU-only image: real TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute.

VMEM footprint per grid step (fp32 words):
    x tile        bm*d
    w1,w3 tiles   2*d*bf
    w2 tile       bf*d
    out tile      bm*d
so ``vmem_bytes = 4*(2*bm*d + 3*d*bf)`` — reported by
``vmem_footprint_bytes`` and recorded in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: multiples of the 128-lane MXU dimension. Chosen by
# the §Perf sweep (kernels/perf_report.py): full MXU utilization, and the
# largest row tile under half the VMEM budget — the x tile is reused
# across the f loop, so HBM weight traffic scales as 1/bm (bm clamps to
# the token count at call time, so small models are unaffected).
DEFAULT_BM = 512
DEFAULT_BF = 256


def _swiglu_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    """One (row-block, ffn-block) grid step.

    Computes ``(silu(x @ w1_blk) * (x @ w3_blk)) @ w2_blk`` and
    accumulates into the output row block.  SwiGLU's elementwise gate
    commutes with the f-dimension split, so block-wise accumulation is
    exact (unlike e.g. softmax, which needs the online trick — see
    flash_attention.py).
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    gate = jax.nn.silu(jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32))
    up = jnp.dot(x, w3_ref[...], preferred_element_type=jnp.float32)
    h = (gate * up).astype(x.dtype)
    o_ref[...] += jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bf"))
def swiglu_ffn(x, w1, w3, w2, *, bm: int = DEFAULT_BM, bf: int = DEFAULT_BF):
    """Fused SwiGLU FFN via Pallas.

    x: [T, d]; w1, w3: [d, f]; w2: [f, d]  ->  [T, d]

    Requires ``T % bm == 0`` and ``f % bf == 0``; the L2 model pads the
    token dimension to a multiple of ``bm`` before calling.
    """
    t, d = x.shape
    f = w1.shape[1]
    bm = min(bm, t)
    bf = min(bf, f)
    assert t % bm == 0, f"token dim {t} not divisible by row tile {bm}"
    assert f % bf == 0, f"ffn dim {f} not divisible by col tile {bf}"
    grid = (t // bm, f // bf)
    return pl.pallas_call(
        _swiglu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),   # x row tile, reused across j
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),   # w1 column tile
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),   # w3 column tile
            pl.BlockSpec((bf, d), lambda i, j: (j, 0)),   # w2 row tile
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(x, w1, w3, w2)


# --------------------------------------------------------------------------
# Autodiff wrapper: Pallas forward, ref-VJP backward.  pallas_call has no
# automatic transpose rule, so the train step differentiates through the
# pure-jnp oracle (numerically identical — pytest asserts so) while the
# forward runs the fused kernel.
# --------------------------------------------------------------------------

@jax.custom_vjp
def swiglu_ffn_ad(x, w1, w3, w2):
    return swiglu_ffn(x, w1, w3, w2)


def _swiglu_fwd(x, w1, w3, w2):
    return swiglu_ffn(x, w1, w3, w2), (x, w1, w3, w2)


def _swiglu_bwd(res, g):
    from compile.kernels import ref as kref

    _, vjp = jax.vjp(kref.swiglu_ffn_ref, *res)
    return vjp(g)


swiglu_ffn_ad.defvjp(_swiglu_fwd, _swiglu_bwd)


def vmem_footprint_bytes(d: int, f: int, bm: int = DEFAULT_BM, bf: int = DEFAULT_BF,
                         bytes_per_el: int = 4) -> int:
    """Static VMEM footprint estimate for one grid step (see module doc)."""
    bf = min(bf, f)
    return bytes_per_el * (2 * bm * d + 3 * d * bf)


def mxu_utilization_estimate(d: int, f: int, bm: int = DEFAULT_BM, bf: int = DEFAULT_BF) -> float:
    """Fraction of MXU-issue slots doing useful work for one grid step.

    The MXU is a 128x128 systolic array; a matmul tile of shape
    [bm, d] @ [d, bf] keeps it busy for ceil(bm/128)*ceil(bf/128)*ceil(d/128)
    passes, each fully utilized only when the dims are multiples of 128.
    """
    import math

    def eff(m, k, n):
        passes = math.ceil(m / 128) * math.ceil(k / 128) * math.ceil(n / 128)
        return (m * k * n) / (passes * 128 ** 3)

    bf = min(bf, f)
    # three matmuls per grid step: x@w1, x@w3 ([bm,d]@[d,bf]), h@w2 ([bm,bf]@[bf,d])
    flops = 2 * bm * d * bf * 2 + 2 * bm * bf * d
    util = (eff(bm, d, bf) * 2 * (2 * bm * d * bf) + eff(bm, bf, d) * (2 * bm * bf * d)) / flops
    return util
