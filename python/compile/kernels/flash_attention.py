"""L1 Pallas kernel: flash-style causal attention (online softmax).

The attention score matrix is never materialized in HBM: the grid walks
(head, query-block) pairs and each kernel instance streams key/value
blocks through VMEM, maintaining the numerically-stable online-softmax
running state (m, l, acc) exactly as FlashAttention does with CUDA shared
memory — here the HBM->VMEM schedule is expressed with BlockSpec + an
in-kernel fori_loop over key blocks (DESIGN.md §Hardware-Adaptation).

``interpret=True`` is mandatory on this CPU-only image (Mosaic custom-call
otherwise).

VMEM per grid step (fp32 words): q tile bq*hd, k/v tiles 2*bk*hd,
acc bq*hd, scores bq*bk  ->  ``4*(2*bq*hd + 2*bk*hd + bq*bk)`` bytes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bk: int, causal: bool, sm_scale: float):
    """One (head, query-block) grid step: online softmax over key blocks."""
    bq, hd = q_ref.shape
    t = k_ref.shape[0]
    q = q_ref[...].astype(jnp.float32) * sm_scale
    qi = pl.program_id(1)
    q_offs = qi * bq + jax.lax.iota(jnp.int32, bq)

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (pl.dslice(kb * bk, bk), slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(kb * bk, bk), slice(None))).astype(jnp.float32)
        s = q @ k.T  # [bq, bk]
        if causal:
            k_offs = kb * bk + jax.lax.iota(jnp.int32, bk)
            mask = q_offs[:, None] >= k_offs[None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return m_cur, l_cur, acc

    m0 = jnp.full((bq,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((bq,), dtype=jnp.float32)
    acc0 = jnp.zeros((bq, hd), dtype=jnp.float32)

    if causal:
        # keys strictly after this query block never contribute
        n_kb = (qi + 1) * bq // bk
    else:
        n_kb = t // bk
    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "causal"))
def flash_attention(q, k, v, *, bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    causal: bool = True):
    """Flash attention via Pallas.

    q, k, v: [H, T, hd]  ->  [H, T, hd].  Requires T divisible by bq and bk.
    """
    h, t, hd = q.shape
    bq = min(bq, t)
    bk = min(bk, t)
    assert t % bq == 0 and t % bk == 0, f"seq {t} not divisible by tiles ({bq},{bk})"
    if causal:
        assert bq % bk == 0, "causal pruning requires bq % bk == 0"
    sm_scale = 1.0 / (hd ** 0.5)
    grid = (h, t // bq)
    kernel = functools.partial(_flash_kernel, bk=bk, causal=causal, sm_scale=sm_scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda hh, i: (hh, i, 0)),  # q tile
            pl.BlockSpec((None, t, hd), lambda hh, i: (hh, 0, 0)),   # full k for the head
            pl.BlockSpec((None, t, hd), lambda hh, i: (hh, 0, 0)),   # full v for the head
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda hh, i: (hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t, hd), q.dtype),
        interpret=True,
    )(q, k, v)


# --------------------------------------------------------------------------
# Autodiff wrapper: Pallas forward, ref-VJP backward (see swiglu.py).
# --------------------------------------------------------------------------

def make_flash_attention_ad(causal: bool = True):
    """Build a differentiable flash attention with fixed causality."""

    @jax.custom_vjp
    def attn(q, k, v):
        return flash_attention(q, k, v, causal=causal)

    def fwd(q, k, v):
        return flash_attention(q, k, v, causal=causal), (q, k, v)

    def bwd(res, g):
        from compile.kernels import ref as kref

        _, vjp = jax.vjp(lambda q, k, v: kref.attention_ref(q, k, v, causal=causal), *res)
        return vjp(g)

    attn.defvjp(fwd, bwd)
    return attn


flash_attention_ad_causal = make_flash_attention_ad(causal=True)
flash_attention_ad_full = make_flash_attention_ad(causal=False)


def vmem_footprint_bytes(t: int, hd: int, bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                         bytes_per_el: int = 4) -> int:
    """Static VMEM footprint estimate for one grid step (see module doc)."""
    bq, bk = min(bq, t), min(bk, t)
    return bytes_per_el * (2 * bq * hd + 2 * bk * hd + bq * bk)
